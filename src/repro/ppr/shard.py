"""Start-vertex-range sharding of the PPR walk index (DESIGN.md §14).

The walk index is O(V·R·L) — by far the largest serving-side state
(537 MB at the bench scale) — and until now lived replicated on one
device while the rank path was already sharded (kernels/pagerank_spmv/
shard.py).  This module partitions ``WalkIndex.steps`` by contiguous
start-vertex ranges over the same ``model`` mesh axis:

  ``steps: int32[S, vps, R, L]``   shard s owns walks started at global
                                   vertices [s·vps, (s+1)·vps); rows
                                   past V (last-shard padding) are all
                                   ``-1`` — inert for staleness and
                                   queries alike.

What makes range sharding *free* correctness-wise is the PRNG
discipline of walks.py: every draw is a pure function of (base_key,
**global** flat walk id, hop).  A shard maps local row (vl, r) to the
global id (s·vps + vl)·R + r (``lax.axis_index`` under shard_map) and
feeds it to the same fold_in stream, so per-shard build and repair are
bitwise identical to the single-device ones — asserted in
tests/test_ppr.py.  The CSR view and the touched mask stay replicated:
walks *visit* arbitrary global vertices even though they are *owned* by
start vertex, and the CSR is O(E) against the O(V·R·L) steps.

Staleness routing follows the delta-routing idiom of the SpMV shard
layer: each shard detects its own stale walks from the replicated
touched mask, compacts them (stable flat order, sentinel-padded) to a
shared pow2 capacity chosen from the max per-shard stale count — one
host sync, the same cost class as the single-device ``int(jnp.sum)`` —
and overflow against an explicit budget is a checked
``ShardCapacityError`` naming the shards, never silent truncation.
Compiled shard_map programs are cached per (mesh, geometry, capacity)
with the same bounded-eviction scheme as ``build_sharded_apply``.

Queries never reassemble the index: each shard segment-sums the visit
counts of the sources it owns and one psum of the f64[V] estimate
(8·V bytes) crosses the wire — vs shipping the multi-hundred-MB steps
array (comm-volume table: DESIGN.md §14).

Off-TPU, shard_map resampling always takes the jnp path — interpret-
mode Pallas is not SPMD-safe under shard_map on jax 0.4.x (DESIGN.md
§9); the walk-repair kernel engages under shard_map only on real TPU.
"""
from __future__ import annotations

import collections
import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.graph.structure import CSRView, EdgeListGraph
from repro.kernels.pagerank_spmv.shard import ShardCapacityError
from repro.obs import trace as obs_trace
from repro.ppr.repair import (_device_csr, _resample_impl,
                              _resample_kernel_impl, _stale_ids, stale_walks)
from repro.ppr.walks import IndexConfig, WalkIndex, _build_steps_range

# compiled-program builds per kind — tests assert a temporal stream
# reuses one program per (geometry, capacity), like the SpMV layer
TRACE_COUNTS: collections.Counter = collections.Counter()

_COMPILED_CACHE: dict = {}
_MAX_CACHED = 8


class WalkShardSpec(NamedTuple):
    """Static geometry of a sharded walk index (hashable: jit/cache key)."""

    num_shards: int
    vertices_per_shard: int
    num_vertices: int

    @property
    def padded_vertices(self) -> int:
        return self.num_shards * self.vertices_per_shard


def make_walk_shard_spec(num_vertices: int, num_shards: int) -> WalkShardSpec:
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    vps = -(-num_vertices // num_shards)
    return WalkShardSpec(num_shards=num_shards, vertices_per_shard=vps,
                         num_vertices=num_vertices)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedWalkIndex:
    """Range-sharded walk index; a pytree, safe under jit/shard_map.

    ``csr``/``key`` are replicated; only ``steps`` is partitioned.  The
    mesh rides along as a static so query/repair dispatch (and the
    serving snapshot that carries this object) need no side channel;
    ``mesh=None`` runs every collective as its vmap host oracle — the
    mesh-free differential path the tests compare against.
    """

    steps: jax.Array     # int32[S, vps, R, L]; -1 = terminated / padding
    csr: CSRView         # replicated adjacency the walks are valid for
    key: jax.Array       # uint32[2] base PRNG key (shared by all shards)
    num_walks: int = dataclasses.field(metadata=dict(static=True))
    max_len: int = dataclasses.field(metadata=dict(static=True))
    alpha: float = dataclasses.field(metadata=dict(static=True))
    spec: WalkShardSpec = dataclasses.field(metadata=dict(static=True))
    mesh: Optional[Mesh] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def num_vertices(self) -> int:
        return self.spec.num_vertices

    @property
    def num_shards(self) -> int:
        return self.spec.num_shards

    def nbytes(self) -> int:
        return self.steps.size * 4


def _usable_mesh(index: ShardedWalkIndex) -> Optional[Mesh]:
    m = index.mesh
    if m is None or m.shape.get("model") != index.spec.num_shards:
        return None
    return m


def _cached(cache_key, builder):
    fn = _COMPILED_CACHE.get(cache_key)
    if fn is None:
        while len(_COMPILED_CACHE) >= _MAX_CACHED:
            _COMPILED_CACHE.pop(next(iter(_COMPILED_CACHE)))
        TRACE_COUNTS[f"build_{cache_key[0]}"] += 1
        fn = builder()
        _COMPILED_CACHE[cache_key] = fn
    return fn


# ---------------------------------------------------------------------------
# shard / unshard / build
# ---------------------------------------------------------------------------

def shard_walk_index(index: WalkIndex, num_shards: int,
                     mesh: Optional[Mesh] = None) -> ShardedWalkIndex:
    """Partition a single-device index by start-vertex range.  Padding
    rows (global vertex ≥ V on the last shard) are all ``-1``."""
    V, R, L = index.steps.shape
    spec = make_walk_shard_spec(V, num_shards)
    pad = spec.padded_vertices - V
    steps = index.steps
    if pad:
        steps = jnp.concatenate(
            [steps, jnp.full((pad, R, L), -1, jnp.int32)])
    steps = steps.reshape(spec.num_shards, spec.vertices_per_shard, R, L)
    if mesh is not None:
        steps = jax.device_put(steps, NamedSharding(mesh, P("model")))
    return ShardedWalkIndex(steps=steps, csr=index.csr, key=index.key,
                            num_walks=index.num_walks,
                            max_len=index.max_len, alpha=index.alpha,
                            spec=spec, mesh=mesh)


def unshard_walk_index(index: ShardedWalkIndex) -> WalkIndex:
    """Reassemble the single-device index (tests/benchmarks only — the
    serving path never does this)."""
    S, vps, R, L = index.steps.shape
    steps = index.steps.reshape(S * vps, R, L)[: index.spec.num_vertices]
    return WalkIndex(steps=steps, csr=index.csr, key=index.key,
                     num_walks=index.num_walks, max_len=index.max_len,
                     alpha=index.alpha)


def _build_build_fn(mesh: Mesh, spec: WalkShardSpec, num_walks: int,
                    max_len: int, alpha: float):
    vps = spec.vertices_per_shard

    def step(csr, key):
        s = jax.lax.axis_index("model").astype(jnp.int32)
        local = _build_steps_range(csr, key, s * vps, spec.num_vertices,
                                   vps, num_walks, max_len, alpha)
        return local[None]

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P(), P()), out_specs=P("model"),
        check_vma=False))


def build_sharded_walk_index(graph: EdgeListGraph,
                             config: IndexConfig = IndexConfig(), *,
                             num_shards: Optional[int] = None,
                             mesh: Optional[Mesh] = None
                             ) -> ShardedWalkIndex:
    """Sample the index directly in sharded form — each shard builds its
    own start-vertex range with global walk ids, so the result equals
    ``shard_walk_index(build_walk_index(graph, config), S)`` bitwise."""
    if num_shards is None:
        if mesh is None:
            raise ValueError("need num_shards or a mesh")
        num_shards = mesh.shape["model"]
    spec = make_walk_shard_spec(graph.num_vertices, num_shards)
    key = jax.random.PRNGKey(config.seed)
    csr = graph.to_device_csr()
    R, L, alpha = config.num_walks, config.max_len, config.alpha
    if mesh is not None and mesh.shape.get("model") == num_shards:
        fn = _cached(("build", mesh, spec, R, L, alpha),
                     lambda: _build_build_fn(mesh, spec, R, L, alpha))
        steps = fn(csr, key)
    else:
        vps = spec.vertices_per_shard
        steps = jnp.stack([
            _build_steps_range(csr, key, jnp.int32(s * vps),
                               spec.num_vertices, vps, R, L, alpha)
            for s in range(num_shards)])
    return ShardedWalkIndex(steps=steps, csr=csr, key=key, num_walks=R,
                            max_len=L, alpha=alpha, spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# staleness + repair
# ---------------------------------------------------------------------------

@jax.jit
def _stale_stacked_host(steps_stacked: jax.Array, touched: jax.Array):
    """Mesh-free oracle: per-shard (count, stale, t0) via vmap."""

    def per(local):
        stale, t0 = stale_walks(local, touched)
        return jnp.sum(stale.astype(jnp.int32)), stale, t0

    return jax.vmap(per)(steps_stacked)


def _build_stale_fn(mesh: Mesh):
    def step(steps, touched):
        stale, t0 = stale_walks(steps[0], touched)
        return (jnp.sum(stale.astype(jnp.int32))[None],
                stale[None], t0[None])

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P("model"), P()),
        out_specs=(P("model"), P("model"), P("model")), check_vma=False))


def _build_repair_fn(mesh: Mesh, spec: WalkShardSpec, num_walks: int,
                     alpha: float, cap: int, use_kernel: bool):
    nl = spec.vertices_per_shard * num_walks

    def step(steps, stale, t0, csr, key):
        s = jax.lax.axis_index("model").astype(jnp.int32)
        ids, t0_sel = _stale_ids(stale[0], t0[0], cap)
        if use_kernel:
            new = _resample_kernel_impl(csr, key, steps[0], ids, t0_sel,
                                        alpha, id_offset=s * nl)
        else:
            new = _resample_impl(csr, key, steps[0], ids, t0_sel, alpha,
                                 id_offset=s * nl)
        return new[None]

    return jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P("model"), P("model"), P("model"), P(), P()),
        out_specs=P("model"), check_vma=False))


@partial(jax.jit, static_argnames=("cap", "alpha"))
def _repair_stacked_host(steps_stacked: jax.Array, csr: CSRView,
                         key: jax.Array, stale: jax.Array, t0: jax.Array,
                         cap: int, alpha: float) -> jax.Array:
    """Mesh-free oracle for the sharded resample (always the jnp path —
    no vmap over the Pallas kernel)."""
    S, vps, R, L = steps_stacked.shape
    offs = jnp.arange(S, dtype=jnp.int32) * (vps * R)

    def per(local, st, t0_l, off):
        ids, t0_sel = _stale_ids(st, t0_l, cap)
        return _resample_impl(csr, key, local, ids, t0_sel, alpha,
                              id_offset=off)

    return jax.vmap(per)(steps_stacked, stale, t0, offs)


def repair_walk_index_sharded(index: ShardedWalkIndex,
                              graph_new: EdgeListGraph,
                              touched: jax.Array, *,
                              min_capacity: int = 64,
                              capacity: Optional[int] = None,
                              check: bool = True,
                              use_kernel: bool = False
                              ) -> Tuple[ShardedWalkIndex, int]:
    """Sharded twin of ``repair_walk_index``: every shard repairs its own
    stale walks under shard_map; the result is bitwise equal to
    unsharding, repairing on one device, and resharding.

    ``capacity`` pins an explicit per-shard compaction budget; a shard
    whose stale count exceeds it raises ``ShardCapacityError`` naming
    the shards (``check=False`` drops the overflow instead — those
    walks simply stay stale, degrading estimates, never corrupting
    them).  Without it the budget is the shard-local walk count, which
    cannot overflow.  ``use_kernel`` engages the Pallas repair kernel;
    under shard_map it takes effect only on real TPU (DESIGN.md §9).
    """
    tr = obs_trace.get_tracer()
    s0 = tr.now()
    S, vps, R, L = index.steps.shape
    spec = index.spec
    csr_new = _device_csr(graph_new)
    mesh = _usable_mesh(index)
    if mesh is not None:
        fn = _cached(("stale", mesh, S, vps, R, L),
                     lambda: _build_stale_fn(mesh))
        counts, stale, t0 = fn(index.steps, touched)
    else:
        counts, stale, t0 = _stale_stacked_host(index.steps, touched)
    counts_h = np.asarray(counts)            # the one host sync per batch
    num_stale = int(counts_h.sum())
    max_stale = int(counts_h.max())
    TRACE_COUNTS["repairs"] += 1
    if num_stale == 0:
        tr.record("ppr.repair_sharded", s0, tr.now() - s0, stale=0,
                  shards=S)
        return dataclasses.replace(index, csr=csr_new), 0
    nl = vps * R
    budget = nl if capacity is None else min(capacity, nl)
    if max_stale > budget:
        over = [s for s, c in enumerate(counts_h.tolist()) if c > budget]
        if check:
            raise ShardCapacityError(
                f"stale-walk compaction overflow: {max_stale} stale walks "
                f"on one shard exceed the budget {budget} on shards {over} "
                f"(raise capacity or repair unsharded)", shards=over)
        TRACE_COUNTS["dropped_stale"] += sum(
            int(c) - budget for c in counts_h if int(c) > budget)
    # shared pow2 capacity from the max per-shard count: every shard runs
    # the same executable, streams reuse a handful of capacities
    cap = min(budget,
              max(min_capacity,
                  1 << (min(max_stale, budget) - 1).bit_length()))
    kern = use_kernel and jax.default_backend() == "tpu"
    if mesh is not None:
        rfn = _cached(("repair", mesh, spec, R, L, cap, kern),
                      lambda: _build_repair_fn(mesh, spec, R,
                                               index.alpha, cap, kern))
        steps = rfn(index.steps, stale, t0, csr_new, index.key)
    else:
        steps = _repair_stacked_host(index.steps, csr_new, index.key,
                                     stale, t0, cap, index.alpha)
    tr.sync(steps)
    tr.record("ppr.repair_sharded", s0, tr.now() - s0, stale=num_stale,
              capacity=cap, shards=S)
    return dataclasses.replace(index, steps=steps, csr=csr_new), num_stale


def shard_stale_counts(index: ShardedWalkIndex, touched: jax.Array
                       ) -> np.ndarray:
    """int per-shard stale-walk counts — the load-balance signal
    bench_ppr's modeled scaling row is derived from."""
    counts, _, _ = _stale_stacked_host(index.steps, touched)
    return np.asarray(counts)


# ---------------------------------------------------------------------------
# queries: per-shard segment_sum + one psum
# ---------------------------------------------------------------------------

def _build_counts_fn(mesh: Mesh, spec: WalkShardSpec):
    from repro.ppr.query import _counts_local
    vps, V = spec.vertices_per_shard, spec.num_vertices

    def step(steps, sources, weights):
        s = jax.lax.axis_index("model").astype(jnp.int32)
        c = _counts_local(steps[0], sources, weights, s * vps, V)
        return jax.lax.psum(c, "model")

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P("model"), P(), P()), out_specs=P(),
        check_vma=False))


@partial(jax.jit, static_argnames=("num_vertices",))
def _counts_stacked_host(steps_stacked: jax.Array, sources: jax.Array,
                         weights: jax.Array, num_vertices: int) -> jax.Array:
    from repro.ppr.query import _counts_local
    S, vps = steps_stacked.shape[0], steps_stacked.shape[1]
    v0 = jnp.arange(S, dtype=jnp.int32) * vps
    per = jax.vmap(
        lambda st, v: _counts_local(st, sources, weights, v, num_vertices)
    )(steps_stacked, v0)
    return jnp.sum(per, axis=0)


def sharded_counts(index: ShardedWalkIndex, sources: jax.Array,
                   weights: jax.Array) -> jax.Array:
    """f64[V] visit-count aggregation over the sharded rows: each shard
    segment-sums the sources it owns, one psum crosses the mesh."""
    mesh = _usable_mesh(index)
    if mesh is not None:
        fn = _cached(("counts", mesh, index.spec),
                     lambda: _build_counts_fn(mesh, index.spec))
        return fn(index.steps, sources, weights)
    return _counts_stacked_host(index.steps, sources, weights,
                                index.spec.num_vertices)


def sharded_ppr_estimate(index: ShardedWalkIndex, seeds: Sequence[int],
                         normalize: bool = True, unroll: bool = True
                         ) -> jax.Array:
    """Sharded twin of ``query.ppr_estimate`` — same estimator math, the
    counts stage runs per shard (matches the single-device estimate to
    f64 rounding; summation order differs across shards)."""
    from repro.ppr import query as q

    idx, mask = q._pad_seeds(seeds, index.num_vertices)
    R, alpha = index.num_walks, index.alpha
    deg = index.csr.deg.astype(jnp.float64)
    if not unroll:
        n_seeds = jnp.maximum(jnp.sum(mask.astype(jnp.float64)), 1.0)
        w = jnp.where(mask, (1.0 - alpha) / (R * n_seeds), 0.0)
        est = sharded_counts(index, idx, w)
    else:
        nbr_cap = q._nbr_cap(index, idx, mask)
        width = min(nbr_cap, q._MAX_NBR_WIDTH)
        est = None
        for offset in range(0, nbr_cap, width):
            nbr, w_nbr = q._nbr_slab(index.csr.indptr, index.csr.indices,
                                     deg, alpha, idx, mask,
                                     jnp.asarray(offset, jnp.int32),
                                     width, R)
            c = sharded_counts(index, nbr, w_nbr)
            est = c if est is None else est + c
        est = q._seed_point_mass(est, deg, alpha, idx, mask)
    if normalize:
        est = est / jnp.maximum(jnp.sum(est), 1e-300)
    return est
