"""Error accounting for the walk index: trade R (memory/build time) for ε.

Two regimes, both per-vertex pointwise bounds for a single-seed query:

* **Sampling error** — each walk's contribution to est(v) lies in
  [0, (1-α)·L] (a walk can visit v at most L times), so Hoeffding gives

      P(|est(v) − E est(v)| ≥ ε) ≤ 2·exp(−2 R ε² / ((1−α)L)²)

  This is deliberately conservative (revisits are rare on non-trivial
  graphs); treat it as a worst-case sizing rule, and the endpoint-bound
  variant (c = 1−α) as the optimistic floor.

* **Truncation bias** — walks are capped at L slots (L-1 transitions);
  the lost tail mass is α^(L-1) of the PPR distribution (geometric
  continue-probability α), i.e. ~4.6e-2 at the α=0.85, L=20 defaults
  and ~8.7e-2 at the serving default L=16.  ``normalize=True`` in the
  query path redistributes it proportionally.

``diagnostics`` reports the realised index shape (mean walk length,
truncated fraction, bytes) so serving can monitor whether the sampled
walks match the geometric model the bounds assume.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence

import jax.numpy as jnp
import numpy as np

from repro.ppr.walks import WalkIndex


def truncation_bias(alpha: float, max_len: int) -> float:
    """PPR mass beyond the L-hop cap: α^L (slot 0 is the source, so the
    cap allows L-1 transitions ⇒ bias α^(L-1) visits-wise; report the
    conservative exponent)."""
    return float(alpha) ** int(max_len - 1)


def walks_for_error(eps: float, delta: float, alpha: float,
                    max_len: int, per_visit_cap: bool = True) -> int:
    """Smallest R with P(|est − E| ≥ eps) ≤ delta per vertex (Hoeffding).

    ``per_visit_cap=True`` uses the conservative c = (1−α)L walk
    contribution; False uses the endpoint-estimator bound c = 1−α.
    """
    if not (0 < eps and 0 < delta < 1):
        raise ValueError("need eps > 0 and 0 < delta < 1")
    c = (1.0 - alpha) * (max_len if per_visit_cap else 1.0)
    return max(1, math.ceil(c * c * math.log(2.0 / delta) / (2.0 * eps * eps)))


def error_bound(num_walks: int, delta: float, alpha: float,
                max_len: int, per_visit_cap: bool = True) -> float:
    """The ε guaranteed at confidence 1−δ by R walks (inverse of
    ``walks_for_error``)."""
    if not (num_walks >= 1 and 0 < delta < 1):
        raise ValueError("need num_walks >= 1 and 0 < delta < 1")
    c = (1.0 - alpha) * (max_len if per_visit_cap else 1.0)
    return c * math.sqrt(math.log(2.0 / delta) / (2.0 * num_walks))


# Effective sample floor for serving top-k from the index (mode="auto"):
# one query over seed set S aggregates Σ_s d_s·R walks (query.py unrolls
# each seed through its out-neighbours' walk sets).  Below ~512 effective
# walks the top-10 tail of a 100k-vertex power-law graph is noise-ranked
# (measured: p@10 ≈ 0.85 at 256–512, ≥ 0.98 at 512+ with paper-scale
# R=64); at or above it the index answer is serving-grade.  Thin (cold)
# seeds route to the exact solver instead — the Hoeffding machinery above
# gives the scaling, this constant pins the empirical operating point.
DEFAULT_MIN_EFFECTIVE_WALKS = 512


def effective_walks(index: WalkIndex, seeds: Sequence[int]) -> int:
    """Σ_s out_degree(s) · R — walks the unrolled estimator aggregates for
    this seed set; the routing signal for QueryClient mode=\"auto\"."""
    s = np.unique(np.asarray(seeds, np.int64).reshape(-1))
    # gather + reduce on device: this runs per auto-routed query, and
    # pulling the whole [V] degree vector to the host would cost more
    # than the fast path it is routing to
    deg_sum = int(jnp.sum(index.csr.deg[jnp.asarray(s, jnp.int32)]))
    return deg_sum * index.num_walks


def diagnostics(index: WalkIndex) -> Dict[str, float]:
    """Realised-sample health: walk lengths vs the geometric model."""
    mask = index.mask()
    lengths = jnp.sum(mask, axis=-1)                     # [V, R] incl. source
    mean_len = float(jnp.mean(lengths))
    # a walk still alive in the last slot was truncated by the L cap
    truncated = float(jnp.mean(mask[:, :, -1]))
    return dict(
        num_walks=float(index.num_walks),
        max_len=float(index.max_len),
        mean_length=mean_len,
        # geometric model: E[len] = 1/(1-α), capped at L
        expected_length=min(1.0 / (1.0 - index.alpha), float(index.max_len)),
        truncated_frac=truncated,
        truncation_bias=truncation_bias(index.alpha, index.max_len),
        nbytes=float(index.nbytes()),
    )


def precision_at_k(approx_top: Sequence[int], exact_ranks: np.ndarray,
                   k: int, rel_tol: float = 0.05) -> float:
    """Tie-tolerant precision@k — the accuracy metric bench_ppr and the
    oracle tests report.

    Exact PPR vectors on real graphs have *tie classes* (e.g. a seed's
    thirty ~equal-weight neighbours): any ordering inside a class is
    equally correct, and the exact solver's own top-k is one arbitrary
    pick.  So the eligible set is every vertex whose exact value is
    within ``rel_tol`` of the k-th largest, and precision is the
    fraction of the approximate top-k drawn from it.
    """
    exact_ranks = np.asarray(exact_ranks, np.float64).reshape(-1)
    approx = np.asarray(approx_top).reshape(-1)[:k]
    kth = np.partition(exact_ranks, -k)[-k]
    eligible = exact_ranks >= kth * (1.0 - rel_tol)
    return float(np.sum(eligible[approx])) / max(1, len(approx))
