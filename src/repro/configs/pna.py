"""pna [arXiv:2004.05718; paper]: 4L d_hidden=75, aggregators
mean/max/min/std × scalers identity/amplification/attenuation."""
import dataclasses

from repro.configs.common import ArchSpec, gnn_shapes
from repro.models.gnn import PNAConfig

CONFIG = PNAConfig(name="pna", n_layers=4, d_hidden=75, d_in=16,
                   n_classes=10)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=12, d_in=6,
                            n_classes=3)

SPEC = ArchSpec(arch_id="pna", family="gnn", config=CONFIG,
                smoke_config=SMOKE, shapes=gnn_shapes())
