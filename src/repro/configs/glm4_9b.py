"""glm4-9b [hf:THUDM/glm-4-9b; hf]: dense LM, 40L d_model=4096 32H
GQA(kv=2) d_ff=13696 vocab=151552, RoPE, full attention."""
import dataclasses

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, rope_theta=10000.0)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, dtype="float32")

SPEC = ArchSpec(
    arch_id="glm4-9b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(full_attention_only=True))
