"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]: MoE LM, 35L
d_model=7168 56H GQA(kv=8) dense d_ff=4864, vocab=32000, 128 experts top-2
PLUS dense residual MLP (dense+MoE hybrid)."""
import dataclasses

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, n_experts=128, top_k=2, moe_d_ff=4864,
    dense_residual=True, rope_theta=10000.0)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=512, n_experts=8, top_k=2, moe_d_ff=64, dense_residual=True,
    dtype="float32")

SPEC = ArchSpec(
    arch_id="arctic-480b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(full_attention_only=True))
