"""--arch <id> resolution.  10 assigned architectures + the paper's own."""
from __future__ import annotations

from typing import Dict

from repro.configs import (arctic_480b, deepfm, gemma3_12b, glm4_9b,
                           graphcast, graphsage_reddit, nequip,
                           pagerank_graphs, pna, qwen2_5_3b,
                           qwen3_moe_30b_a3b)
from repro.configs.common import ArchSpec

_SPECS = [
    gemma3_12b.SPEC,
    qwen2_5_3b.SPEC,
    glm4_9b.SPEC,
    qwen3_moe_30b_a3b.SPEC,
    arctic_480b.SPEC,
    graphcast.SPEC,
    graphsage_reddit.SPEC,
    nequip.SPEC,
    pna.SPEC,
    deepfm.SPEC,
    pagerank_graphs.SPEC,
]

REGISTRY: Dict[str, ArchSpec] = {s.arch_id: s for s in _SPECS}

ASSIGNED_ARCHS = [s.arch_id for s in _SPECS if s.family != "pagerank"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def all_cells(include_pagerank: bool = False):
    """Every (arch, shape) pair — the dry-run/roofline cell list."""
    for spec in _SPECS:
        if spec.family == "pagerank" and not include_pagerank:
            continue
        for cell in spec.shapes.values():
            yield spec, cell
