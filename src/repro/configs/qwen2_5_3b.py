"""qwen2.5-3b [hf:Qwen/Qwen2.5-0.5B family; hf]: dense LM, 36L d_model=2048
16H GQA(kv=2) d_ff=11008 vocab=151936, QKV bias, full attention."""
import dataclasses

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
    d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1_000_000.0)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab=512, dtype="float32")

SPEC = ArchSpec(
    arch_id="qwen2.5-3b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(full_attention_only=True))
