"""The paper's own workload configs: dynamic-graph PageRank at scale.

Graph size classes mirror paper Table 1 (temporal) and Table 2 (large
static).  These drive the distributed-PageRank dry-run (the paper's
technique on the production mesh) — the 40 assigned (arch × shape) cells
are defined in the other config modules.
"""
import dataclasses
from typing import Dict

from repro.configs.common import ArchSpec, ShapeCell


@dataclasses.dataclass(frozen=True)
class PageRankConfig:
    name: str = "df-pagerank"
    alpha: float = 0.85
    tol: float = 1e-10
    frontier_tol: float = 1e-6
    prune_tol: float = 1e-6
    max_iter: int = 500


CONFIG = PageRankConfig()
SMOKE = dataclasses.replace(CONFIG, tol=1e-8)

# V/E classes: sx-stackoverflow (largest temporal), com-Orkut (social),
# sk-2005 (largest web graph in Table 2), europe_osm (road, low degree).
SHAPES: Dict[str, ShapeCell] = {
    "temporal_so": ShapeCell(
        "temporal_so", "pagerank",
        dict(n_vertices=2_601_977, edge_capacity=40_000_000,
             batch_edges=6_340)),       # 1e-4|E_T|
    "social_orkut": ShapeCell(
        "social_orkut", "pagerank",
        dict(n_vertices=3_072_441, edge_capacity=237_000_000,
             batch_edges=23_700)),
    "web_sk2005": ShapeCell(
        "web_sk2005", "pagerank",
        dict(n_vertices=50_636_154, edge_capacity=1_980_000_000,
             batch_edges=198_000)),
    "road_europe": ShapeCell(
        "road_europe", "pagerank",
        dict(n_vertices=50_912_018, edge_capacity=159_000_000,
             batch_edges=15_900)),
}

SPEC = ArchSpec(arch_id="df-pagerank", family="pagerank", config=CONFIG,
                smoke_config=SMOKE, shapes=SHAPES,
                notes="the paper's own workload on the production mesh")
