"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]: MoE LM, 48L d_model=2048
32H GQA(kv=4) per-expert d_ff=768, vocab=151936, 128 experts top-8."""
import dataclasses

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=0, vocab=151936, n_experts=128, top_k=8,
    moe_d_ff=768, rope_theta=1_000_000.0)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=512,
    n_experts=8, top_k=2, moe_d_ff=32, dtype="float32")

SPEC = ArchSpec(
    arch_id="qwen3-moe-30b-a3b", family="lm", config=CONFIG,
    smoke_config=SMOKE, shapes=lm_shapes(full_attention_only=True))
