"""deepfm [arXiv:1703.04247; paper]: 39 sparse fields, embed_dim=10,
MLP 400-400-400, FM interaction.  Criteo-scale tables: 10⁶ rows/field."""
import dataclasses

from repro.configs.common import ArchSpec, recsys_shapes
from repro.models.recsys import DeepFMConfig

CONFIG = DeepFMConfig(name="deepfm", n_sparse=39, embed_dim=10,
                      vocab_per_field=1_000_000, mlp_dims=(400, 400, 400))

SMOKE = dataclasses.replace(CONFIG, vocab_per_field=100,
                            mlp_dims=(32, 32, 32))

SPEC = ArchSpec(arch_id="deepfm", family="recsys", config=CONFIG,
                smoke_config=SMOKE, shapes=recsys_shapes())
