"""Config-system spine: ArchSpec, ShapeCell, per-family shape tables."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input-shape) dry-run cell."""
    name: str
    kind: str                 # train | prefill | decode | gnn_* | recsys_*
    dims: Dict[str, int]
    skip: Optional[str] = None    # reason string when the cell is skipped


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str               # lm | gnn | recsys | pagerank
    config: Any
    smoke_config: Any
    shapes: Dict[str, ShapeCell]
    notes: str = ""


def lm_shapes(full_attention_only: bool) -> Dict[str, ShapeCell]:
    """The LM-family shape set (same four cells for every LM arch).

    ``long_500k`` lowers ``serve_step`` (decode) — linear in context — but
    per the assignment it is skipped for pure full-attention archs and run
    for local/hybrid ones (gemma3's 5:1 local:global qualifies).
    """
    cells = {
        "train_4k": ShapeCell("train_4k", "train",
                              dict(seq=4096, batch=256)),
        "prefill_32k": ShapeCell("prefill_32k", "prefill",
                                 dict(seq=32768, batch=32)),
        "decode_32k": ShapeCell("decode_32k", "decode",
                                dict(ctx=32768, batch=128)),
        "long_500k": ShapeCell(
            "long_500k", "decode", dict(ctx=524288, batch=1),
            skip=("pure full-attention arch: 500k-context cell skipped per "
                  "assignment (no sub-quadratic mechanism)"
                  ) if full_attention_only else None),
    }
    return cells


def gnn_shapes() -> Dict[str, ShapeCell]:
    return {
        "full_graph_sm": ShapeCell(
            "full_graph_sm", "gnn_full",
            dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
        "minibatch_lg": ShapeCell(
            "minibatch_lg", "gnn_minibatch",
            dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
                 fanout0=15, fanout1=10)),
        "ogb_products": ShapeCell(
            "ogb_products", "gnn_full",
            dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100)),
        "molecule": ShapeCell(
            "molecule", "gnn_molecule",
            dict(n_nodes=30, n_edges=64, batch=128)),
    }


def recsys_shapes() -> Dict[str, ShapeCell]:
    return {
        "train_batch": ShapeCell("train_batch", "recsys_train",
                                 dict(batch=65536)),
        "serve_p99": ShapeCell("serve_p99", "recsys_serve",
                               dict(batch=512)),
        "serve_bulk": ShapeCell("serve_bulk", "recsys_serve",
                                dict(batch=262144)),
        "retrieval_cand": ShapeCell("retrieval_cand", "recsys_retrieval",
                                    dict(batch=1, n_candidates=1_000_000)),
    }
