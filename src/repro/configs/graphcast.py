"""graphcast [arXiv:2212.12794; unverified]: encoder-processor-decoder
mesh GNN, 16L d_hidden=512 sum aggregator, n_vars=227, mesh refinement 6."""
import dataclasses

from repro.configs.common import ArchSpec, gnn_shapes
from repro.models.gnn import GraphCastConfig

CONFIG = GraphCastConfig(name="graphcast", n_layers=16, d_hidden=512,
                         n_vars=227, mesh_refinement=6)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=32, n_vars=11)

SPEC = ArchSpec(
    arch_id="graphcast", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=gnn_shapes(),
    notes="shape n_nodes -> grid nodes; mesh nodes = n_nodes//4; "
          "n_edges -> mesh-mesh edges; g2m/m2g = 2 per grid node")
