"""nequip [arXiv:2101.03164; paper]: 5L 32ch l_max=2 n_rbf=8 cutoff=5,
E(3)-equivariant restricted tensor product (see DESIGN.md for the
CG-restriction note)."""
import dataclasses

from repro.configs.common import ArchSpec, gnn_shapes
from repro.models.gnn import NequIPConfig

CONFIG = NequIPConfig(name="nequip", n_layers=5, channels=32, l_max=2,
                      n_rbf=8, cutoff=5.0, n_species=4)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, channels=8, n_rbf=4)

SPEC = ArchSpec(
    arch_id="nequip", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=gnn_shapes(),
    notes="graph shapes map to atom-neighbour graphs; features are "
          "(species, positions); d_feat dims reinterpreted as species "
          "count context")
