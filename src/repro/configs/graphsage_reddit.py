"""graphsage-reddit [arXiv:1706.02216; paper]: 2L d_hidden=128 mean
aggregator, sample sizes 25-10 (Reddit: 232,965 nodes / 114.6M edges)."""
import dataclasses

from repro.configs.common import ArchSpec, gnn_shapes
from repro.models.gnn import SAGEConfig

CONFIG = SAGEConfig(name="graphsage-reddit", n_layers=2, d_hidden=128,
                    d_in=602, n_classes=41, fanouts=(25, 10))

SMOKE = dataclasses.replace(CONFIG, d_hidden=16, d_in=12, n_classes=5,
                            fanouts=(3, 2))

SPEC = ArchSpec(
    arch_id="graphsage-reddit", family="gnn", config=CONFIG,
    smoke_config=SMOKE, shapes=gnn_shapes(),
    notes="DF frontier integrates: incremental embedding refresh "
          "(core/incremental_gnn.py)")
