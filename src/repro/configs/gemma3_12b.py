"""gemma3-12b [hf:google/gemma-3-1b-pt pattern; unverified]: dense LM,
48L d_model=3840 16H GQA(kv=8) d_ff=15360 vocab=262144, 5:1 local:global
attention (window 1024), 128k context."""
import dataclasses

from repro.configs.common import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, window=1024, global_every=6,
    rope_theta=1_000_000.0)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, window=16, global_every=2, dtype="float32")

SPEC = ArchSpec(
    arch_id="gemma3-12b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=lm_shapes(full_attention_only=False),
    notes="5:1 local:global hybrid -> long_500k decode cell runs")
