"""Family-generic train/serve steps — the functions the dry-run lowers.

Each builder returns a pure ``fn(state..., batch) -> ...`` closure over the
static arch config, suitable for ``jax.jit(...).lower(*input_specs)``.

Distributed-optimization features (DESIGN.md §4):
  * microbatch gradient accumulation (``n_microbatches``) via lax.scan;
  * optional int8/bf16 gradient compression before the optimizer
    (simulating the cross-pod low-precision all-reduce);
  * remat/scan memory policy lives in the model definitions.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.optim.adamw import AdamWState, adamw_update, init_adamw
from repro.optim.compression import compress_tree
from repro.optim.schedules import warmup_cosine


def _accumulate_grads(loss_fn, params, batch, n_micro: int,
                      accum_dtype=jnp.float32):
    """Split the batch leading dim into n_micro slices and average grads.

    ``accum_dtype=bf16`` halves the resident grad accumulator — used for
    arctic-480b where the f32 accumulator alone is 7.5 GB/device.
    """
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def micro(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grad_acc = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(accum_dtype), grad_acc, grads)
        return (loss_acc + loss, grad_acc), None

    reshaped = jax.tree_util.tree_map(
        lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
        batch)
    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss, grads), _ = jax.lax.scan(
        micro, (jnp.zeros((), jnp.float32), zero_grads), reshaped)
    scale = 1.0 / n_micro
    return loss * scale, jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def make_lm_train_step(cfg, *, peak_lr=3e-4, warmup=100, total=10_000,
                       n_microbatches: int = 1,
                       grad_compression: str = "none",
                       factored: bool = False,
                       accum_dtype=jnp.float32) -> Callable:
    def loss_fn(params, batch):
        return T.lm_loss(cfg, params, batch["tokens"], batch["labels"])

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = _accumulate_grads(loss_fn, params, batch,
                                        n_microbatches, accum_dtype)
        grads = compress_tree(grads, grad_compression)
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr,
                           warmup_steps=warmup, total_steps=total)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         factored=factored)
        return params, opt_state, loss

    return train_step


def make_lm_prefill(cfg) -> Callable:
    def prefill(params, tokens):
        # only the LAST position's logits are needed to start decoding —
        # projecting the full [B,S,V] logits was 640 GB global at
        # prefill_32k on the 152k vocabs (measured; EXPERIMENTS.md §Perf)
        x, _ = T.backbone(cfg, params, tokens)
        logits = jnp.einsum("bd,vd->bv", x[:, -1, :], params.embed,
                            preferred_element_type=jnp.float32)
        return logits

    return prefill


def make_lm_decode_step(cfg) -> Callable:
    def serve_step(params, cache: T.KVCache, tokens):
        return T.decode_step(cfg, params, cache, tokens)

    return serve_step


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _xent(logits, labels, mask=None):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    per = logz - gold
    if mask is not None:
        per = jnp.where(mask, per, 0.0)
        return jnp.sum(per) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(per)


def make_gnn_loss(spec_arch_id: str, cfg) -> Callable:
    """(params, batch) -> scalar loss for each GNN arch."""
    if spec_arch_id == "graphsage-reddit":
        def loss_fn(params, batch):
            if "blocks_parent" in batch:       # minibatch path
                logits = G.sage_block_forward(
                    cfg, params, batch["blocks_feats"],
                    batch["blocks_parent"], batch["blocks_mask"])
                return _xent(logits, batch["labels"])
            logits = G.sage_forward(cfg, params, _graph_batch(batch))
            return _xent(logits, batch["labels"], batch["node_mask"])
        return loss_fn
    if spec_arch_id == "pna":
        def loss_fn(params, batch):
            logits = G.pna_forward(cfg, params, _graph_batch(batch))
            return _xent(logits, batch["labels"], batch["node_mask"])
        return loss_fn
    if spec_arch_id == "nequip":
        def loss_fn(params, batch):
            # vmap over a batch of molecular graphs if present
            if batch["species"].ndim == 2:
                energies = jax.vmap(
                    lambda s, p, es, ed, em: G.nequip_forward(
                        cfg, params, s, p, es, ed, em))(
                    batch["species"], batch["positions"],
                    batch["edge_src"], batch["edge_dst"],
                    batch["edge_mask"])
            else:
                energies = G.nequip_forward(
                    cfg, params, batch["species"], batch["positions"],
                    batch["edge_src"], batch["edge_dst"],
                    batch["edge_mask"])
            return jnp.mean(jnp.square(energies - batch["energy"]))
        return loss_fn
    if spec_arch_id == "graphcast":
        def loss_fn(params, batch):
            pred = G.graphcast_forward(cfg, params, _graph_batch(batch))
            se = jnp.square(pred - batch["targets"])
            m = batch["node_mask"]
            n_valid = jnp.maximum(jnp.sum(m.astype(jnp.float32)), 1.0)
            return jnp.sum(jnp.where(m[:, None], se, 0.0)) \
                / (n_valid * se.shape[-1])
        return loss_fn
    raise KeyError(spec_arch_id)


def _graph_batch(batch) -> G.GraphBatch:
    return G.GraphBatch(
        node_feats=batch["node_feats"],
        edge_src=batch["edge_src"], edge_dst=batch["edge_dst"],
        edge_mask=batch["edge_mask"], node_mask=batch["node_mask"],
        positions=batch.get("positions"),
        mesh_feats=batch.get("mesh_feats"),
        g2m_src=batch.get("g2m_src"), g2m_dst=batch.get("g2m_dst"),
        m2g_src=batch.get("m2g_src"), m2g_dst=batch.get("m2g_dst"))


def make_gnn_train_step(arch_id: str, cfg, *, peak_lr=1e-3) -> Callable:
    loss_fn = make_gnn_loss(arch_id, cfg)

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr,
                           warmup_steps=10, total_steps=1000)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=0.0)
        return params, opt_state, loss

    return train_step


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def make_recsys_train_step(cfg, *, peak_lr=1e-3) -> Callable:
    def loss_fn(params, batch):
        return R.deepfm_loss(cfg, params, batch["sparse_ids"],
                             batch["labels"])

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr,
                           warmup_steps=10, total_steps=10_000)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=0.0)
        return params, opt_state, loss

    return train_step


def make_recsys_serve(cfg) -> Callable:
    def serve(params, batch):
        return jax.nn.sigmoid(
            R.deepfm_forward(cfg, params, batch["sparse_ids"]))

    return serve


def make_recsys_retrieval(cfg) -> Callable:
    def retrieve(params, batch):
        return R.retrieval_score(cfg, params, batch["query_ids"],
                                 batch["cand_ids"])

    return retrieve
