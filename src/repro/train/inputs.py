"""input_specs(): ShapeDtypeStruct stand-ins for every (arch × shape) cell.

``concrete=False`` (default) returns ShapeDtypeStructs — weak-type-correct,
shardable, zero allocation — for ``jit(...).lower()``.  ``concrete=True``
materialises small random arrays with valid index bounds (smoke tests use
this with the reduced configs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import ArchSpec, ShapeCell
from repro.models import transformer as T
from repro.models.gnn import (GraphCastConfig, NequIPConfig, PNAConfig,
                              SAGEConfig, init_graphcast, init_nequip,
                              init_pna, init_sage)
from repro.models.recsys import DeepFMConfig, init_deepfm
from repro.optim.adamw import init_adamw

F32 = jnp.float32
I32 = jnp.int32
BOOL = jnp.bool_


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


class _Builder:
    """Emits either ShapeDtypeStructs or bounded random arrays."""

    def __init__(self, concrete: bool, seed: int = 0):
        self.concrete = concrete
        self.rng = np.random.default_rng(seed)

    def ints(self, shape, bound):
        if not self.concrete:
            return _sds(shape, I32)
        return jnp.asarray(
            self.rng.integers(0, max(bound, 1), size=shape), I32)

    def floats(self, shape, dtype=F32):
        if not self.concrete:
            return _sds(shape, dtype)
        return jnp.asarray(self.rng.standard_normal(shape), dtype)

    def bools(self, shape, frac=1.0):
        if not self.concrete:
            return _sds(shape, BOOL)
        return jnp.asarray(self.rng.random(shape) < frac)


# ---------------------------------------------------------------------------
# per-cell effective model config (shape-dependent dims)
# ---------------------------------------------------------------------------

def effective_config(spec: ArchSpec, cell: ShapeCell, smoke: bool = False):
    cfg = spec.smoke_config if smoke else spec.config
    d = cell.dims
    if spec.family == "gnn" and cell.kind == "gnn_full":
        if isinstance(cfg, (SAGEConfig, PNAConfig)):
            cfg = dataclasses.replace(cfg, d_in=d["d_feat"] if not smoke
                                      else cfg.d_in)
    return cfg


def _gnn_cell_dims(spec: ArchSpec, cell: ShapeCell, smoke: bool
                   ) -> Dict[str, int]:
    """Resolve (N, E, ...) for a gnn cell, reduced when smoke."""
    d = dict(cell.dims)
    if cell.kind == "gnn_minibatch":
        b, f0, f1 = d["batch_nodes"], d["fanout0"], d["fanout1"]
        if smoke:
            b, f0, f1 = 8, 3, 2
        d.update(batch_nodes=b, fanout0=f0, fanout1=f1)
        # subgraph view for non-sampling archs
        d["n_sub_nodes"] = b * (1 + f0 + f0 * f1)
        d["n_sub_edges"] = b * f0 + b * f0 * f1
    elif cell.kind == "gnn_molecule":
        if smoke:
            d.update(batch=4)
    else:
        if smoke:
            d.update(n_nodes=64, n_edges=256, d_feat=d.get("d_feat", 16))
    return d


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def gnn_inputs(spec: ArchSpec, cell: ShapeCell, *, concrete=False,
               smoke=False, seed=0) -> Dict[str, Any]:
    """Node/edge buffers are padded to a 512 multiple (8 for smoke) so the
    production mesh can shard them evenly; the models mask padded slots
    via edge_mask/node_mask (pjit in_shardings demand divisibility)."""
    b = _Builder(concrete, seed)
    cfg = effective_config(spec, cell, smoke)
    d = _gnn_cell_dims(spec, cell, smoke)
    arch = spec.arch_id
    mult = 8 if smoke else 512

    if arch == "graphsage-reddit" and cell.kind == "gnn_minibatch":
        bn, f0, f1 = d["batch_nodes"], d["fanout0"], d["fanout1"]
        b2, b1 = bn * f0 * f1, bn * f0
        din = cfg.d_in
        return dict(
            blocks_feats=[b.floats((b2, din)), b.floats((b1, din)),
                          b.floats((bn, din))],
            blocks_parent=[b.ints((b2,), b1), b.ints((b1,), bn)],
            blocks_mask=[b.bools((b2,)), b.bools((b1,))],
            labels=b.ints((bn,), cfg.n_classes))

    if cell.kind == "gnn_minibatch":
        n, e = d["n_sub_nodes"], d["n_sub_edges"]
        dfeat = getattr(cfg, "d_in", 16)
    elif cell.kind == "gnn_molecule":
        n = d["n_nodes"] * d["batch"]
        e = d["n_edges"] * d["batch"]
        dfeat = getattr(cfg, "d_in", 16)
    else:
        n, e = d["n_nodes"], d["n_edges"]
        dfeat = getattr(cfg, "d_in", d.get("d_feat", 16))
    n, e = _pad_to(n, mult), _pad_to(e, mult)

    if arch == "nequip":
        if cell.kind == "gnn_molecule":
            nb, na, ne = d["batch"], d["n_nodes"], d["n_edges"]
            return dict(
                species=b.ints((nb, na), cfg.n_species),
                positions=b.floats((nb, na, 3)),
                edge_src=b.ints((nb, ne), na),
                edge_dst=b.ints((nb, ne), na),
                edge_mask=b.bools((nb, ne)),
                energy=b.floats((nb,)))
        return dict(
            species=b.ints((n,), cfg.n_species),
            positions=b.floats((n, 3)),
            edge_src=b.ints((e,), n), edge_dst=b.ints((e,), n),
            edge_mask=b.bools((e,)),
            energy=b.floats(()))

    if arch == "graphcast":
        g = n
        m = _pad_to(max(4, n // 4), mult)
        e_g2m = 2 * g
        return dict(
            node_feats=b.floats((g, cfg.n_vars)),
            mesh_feats=b.floats((m, 3)),
            edge_src=b.ints((e,), m), edge_dst=b.ints((e,), m),
            edge_mask=b.bools((e,)),
            node_mask=b.bools((g,)),
            g2m_src=b.ints((e_g2m,), g), g2m_dst=b.ints((e_g2m,), m),
            m2g_src=b.ints((e_g2m,), m), m2g_dst=b.ints((e_g2m,), g),
            targets=b.floats((g, cfg.n_vars)))

    # graphsage full / pna
    return dict(
        node_feats=b.floats((n, dfeat)),
        edge_src=b.ints((e,), n), edge_dst=b.ints((e,), n),
        edge_mask=b.bools((e,)), node_mask=b.bools((n,)),
        labels=b.ints((n,), cfg.n_classes))


def lm_inputs(spec: ArchSpec, cell: ShapeCell, *, concrete=False,
              smoke=False, seed=0) -> Dict[str, Any]:
    b = _Builder(concrete, seed)
    cfg = spec.smoke_config if smoke else spec.config
    d = cell.dims
    if cell.kind == "train":
        bs, s = (2, 64) if smoke else (d["batch"], d["seq"])
        return dict(tokens=b.ints((bs, s), cfg.vocab),
                    labels=b.ints((bs, s), cfg.vocab))
    if cell.kind == "prefill":
        bs, s = (2, 64) if smoke else (d["batch"], d["seq"])
        return dict(tokens=b.ints((bs, s), cfg.vocab))
    # decode
    bs, ctx = (2, 64) if smoke else (d["batch"], d["ctx"])
    return dict(tokens=b.ints((bs, 1), cfg.vocab), ctx=ctx, batch=bs)


def recsys_inputs(spec: ArchSpec, cell: ShapeCell, *, concrete=False,
                  smoke=False, seed=0) -> Dict[str, Any]:
    b = _Builder(concrete, seed)
    cfg = spec.smoke_config if smoke else spec.config
    d = cell.dims
    if cell.kind == "recsys_retrieval":
        nc = 256 if smoke else d["n_candidates"]
        return dict(query_ids=b.ints((1, cfg.n_sparse), cfg.vocab_per_field),
                    cand_ids=b.ints((nc,), cfg.vocab_per_field))
    bs = 16 if smoke else d["batch"]
    out = dict(sparse_ids=b.ints((bs, cfg.n_sparse), cfg.vocab_per_field))
    if cell.kind == "recsys_train":
        if concrete:
            out["labels"] = jnp.asarray(
                np.random.default_rng(seed).random(bs) < 0.5, F32)
        else:
            out["labels"] = _sds((bs,), F32)
    return out


def build_inputs(spec: ArchSpec, cell: ShapeCell, **kw) -> Dict[str, Any]:
    return {"lm": lm_inputs, "gnn": gnn_inputs,
            "recsys": recsys_inputs}[spec.family](spec, cell, **kw)


# ---------------------------------------------------------------------------
# abstract model/optimizer state per arch
# ---------------------------------------------------------------------------

MOMENT_DTYPE = {
    # bf16 moments keep the two MoE giants inside 512×16GB (DESIGN.md §4)
    "arctic-480b": jnp.bfloat16,
    "qwen3-moe-30b-a3b": jnp.bfloat16,
}

# gradient-accumulation microbatches for train_4k (global_batch=256):
# sized so L×B_local×S×D saved scan carries fit HBM (DESIGN.md §4)
MICROBATCHES = {
    "gemma3-12b": 4,
    "qwen2.5-3b": 2,
    "glm4-9b": 4,
    "qwen3-moe-30b-a3b": 4,
    "arctic-480b": 16,
}

# Adafactor-style factored second moments (O(n+m) vs O(nm)) — arctic only
FACTORED_V = {"arctic-480b": True}

# bf16 gradient accumulator for the 480B model (7.5 GB/device at f32)
ACCUM_DTYPE = {"arctic-480b": jnp.bfloat16}


def init_fn(spec: ArchSpec, smoke: bool = False):
    cfg = spec.smoke_config if smoke else spec.config
    if spec.family == "lm":
        return partial(T.init_lm, cfg)
    if spec.family == "recsys":
        return partial(init_deepfm, cfg)
    return {
        "graphsage-reddit": partial(init_sage, cfg),
        "pna": partial(init_pna, cfg),
        "nequip": partial(init_nequip, cfg),
        "graphcast": partial(init_graphcast, cfg),
    }[spec.arch_id]


def abstract_state(spec: ArchSpec, cell: ShapeCell, smoke: bool = False,
                   with_opt: bool = True):
    """(params_shapes, opt_shapes|None) without any allocation."""
    cfg = effective_config(spec, cell, smoke)
    spec_eff = dataclasses.replace(
        spec, config=cfg) if not smoke else spec
    fn = init_fn(spec_eff, smoke)
    params = jax.eval_shape(fn, jax.random.PRNGKey(0))
    if not with_opt:
        return params, None
    mdt = MOMENT_DTYPE.get(spec.arch_id, jnp.float32)
    fac = FACTORED_V.get(spec.arch_id, False)
    opt = jax.eval_shape(
        partial(init_adamw, moment_dtype=mdt, factored=fac), params)
    return params, opt


def abstract_cache(spec: ArchSpec, cell: ShapeCell, smoke: bool = False):
    cfg = spec.smoke_config if smoke else spec.config
    d = cell.dims
    bs, ctx = (2, 64) if smoke else (d["batch"], d["ctx"])
    return jax.eval_shape(partial(T.init_cache, cfg, bs, ctx))
